"""Benchmark driver: one module per paper table/figure + theory + perf.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks.common.emit).
``--fast`` shrinks trial counts for CI; the default sizes reproduce the
paper's qualitative results.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig4,fig5,fig6,table7,theory,perf")
    args = ap.parse_args()

    from . import (fig4_synthetic, fig5_worldbank, fig6_newsgroups,
                   perf_sketch, table7_overlap, theory_check)
    suites = {
        "fig4": fig4_synthetic.run,
        "fig5": fig5_worldbank.run,
        "fig6": fig6_newsgroups.run,
        "table7": table7_overlap.run,
        "theory": theory_check.run,
        "perf": perf_sketch.run,
    }
    only = args.only.split(",") if args.only else list(suites)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in only:
        t = time.time()
        suites[name](fast=args.fast)
        print(f"# {name} done in {time.time()-t:.1f}s", flush=True)
    print(f"# total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
