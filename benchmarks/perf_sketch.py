"""Throughput benchmarks for the sketching hot paths (host + device/interp).

Production framing: dataset-search ingests a lake by sketching every column
(sketch/s matters) and serves queries by estimating against the whole corpus
(pair/s matters).  Device-path numbers on this CPU container exercise the
Pallas interpreter and the jit pipeline, not TPU silicon -- they validate
scaling shape, not absolute speed (the roofline analysis covers TPU).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ICWS, SparseVec, inner_fast, make, stack_wmh
from repro.core.icws import StackedICWS
from repro.data import FAMILY_NAMES, make_family, wmh_storage
from repro.data.corpus import SketchCorpus, pad_sparse_batch
from repro.data.families import TSFamily
from repro.data.merge import merge_stores, partition_by_key
from repro.data.store import CorpusStore
from repro.data.synthetic import sparse_pair
from repro import obs as _obs
from repro.kernels import ops
from repro.kernels.estimate import estimate_fields_pallas
from repro.kernels.icws_sketch import icws_sketch_pallas
from repro.obs.metrics import Histogram
from repro.roofline import autotune
from repro.serve import SketchSearchService

from .common import emit, timed, timed_median


def run(fast: bool = False):
    rng = np.random.default_rng(23)
    pairs = [sparse_pair(rng, overlap=0.1) for _ in range(2 if fast else 4)]
    vecs = [v for p in pairs for v in p]

    # host sketch throughput per method
    for method in ("wmh", "mh", "kmv", "jl", "cs", "icws", "dmh"):
        sk = make(method, 400, seed=0)
        _, us = timed(lambda: [sk.sketch(v) for v in vecs])
        emit(f"perf/sketch/{method}", us / len(vecs),
             f"nnz={vecs[0].nnz} storage=400")

    # batched estimation throughput (the corpus-query hot loop)
    sk = make("wmh", 400, seed=0)
    sketches = [sk.sketch(v) for v in vecs]
    A = stack_wmh(sketches * 50)
    B = stack_wmh(sketches[::-1] * 50)
    _, us = timed(sk.estimate_batch, A, B, repeat=3)
    emit("perf/estimate_batch/wmh", us / A.norm.shape[0], f"pairs={A.norm.shape[0]}")

    # device (Pallas interpret) sketch + fused estimate
    B_, N, m = 4, 512, 256
    w = jnp.asarray(rng.random((B_, N)), jnp.float32)
    w = w / w.sum(axis=1, keepdims=True)
    keys = jnp.asarray(rng.integers(0, 2**31 - 1, (B_, N)), jnp.int32)
    vals = jnp.sqrt(w)
    out = icws_sketch_pallas(w, keys, vals, m=m, seed=0, interpret=True)
    _, us = timed(lambda: icws_sketch_pallas(w, keys, vals, m=m, seed=0,
                                             interpret=True)[0].block_until_ready())
    emit("perf/kernel/icws_sketch", us / B_, f"B={B_} N={N} m={m} interpret=True")

    fp, val, _, _ = out
    na = jnp.ones((B_,), jnp.float32)
    _, us = timed(lambda: ops.icws_estimate(fp, val, na, fp, val, na)
                  .block_until_ready())
    emit("perf/kernel/estimate", us / B_, f"pairs={B_} m={m} interpret=True")

    # device-resident corpus: one-vs-many query hot loop.  The query sketch
    # stays [1, m] end to end -- no stack_wmh([q] * P)-style restacking, no
    # [P, m] query tile; the kernel broadcasts it across the corpus grid.
    P, mc = (16, 128) if fast else (64, 256)
    lake = [sparse_pair(rng, n=600, nnz=120, overlap=0.2)[0]
            for _ in range(P)]
    corpus = SketchCorpus(m=mc, seed=1)
    _, us = timed(lambda: corpus.add_batch(lake))
    emit("perf/corpus/ingest", us / P, f"tables={P} m={mc} interpret=True")

    query = sparse_pair(rng, n=600, nnz=120, overlap=0.2)[0]
    fq, vq, nq, _ = corpus.sketch_query(query)
    corpus.estimate(fq, vq, nq[0]).block_until_ready()      # warm the jit
    dev, us = timed(lambda: corpus.estimate(fq, vq, nq[0]).block_until_ready(),
                    repeat=3)
    emit("perf/corpus/query_1vN", us / P, f"tables={P} m={mc} interpret=True")

    # cross-check: device one-vs-many vs host ICWS estimator on *identical*
    # sketches (the host path is the oracle, and may restack freely)
    fpc, vc, nc = (np.asarray(a) for a in corpus.arrays()[:3])
    A = StackedICWS(fingerprints=np.repeat(np.asarray(fq), P, axis=0),
                    values=np.repeat(np.asarray(vq, np.float64), P, axis=0),
                    norm=np.full(P, float(nq[0]), np.float64))
    B2 = StackedICWS(fingerprints=fpc, values=vc.astype(np.float64),
                     norm=nc.astype(np.float64))
    host, us = timed(ICWS(m=mc, seed=1).estimate_batch, A, B2, repeat=3)
    emit("perf/corpus/query_host_oracle", us / P, f"tables={P} m={mc}")
    dev64 = np.asarray(dev, np.float64)
    scale = np.maximum(np.maximum(np.abs(host), np.abs(dev64)), 1e-12)
    # the asserted quantity IS the emitted quantity (ppm), so the reported
    # bound and the enforced bound can never drift apart again
    rel_ppm = float(np.max(np.abs(dev64 - host) / scale)) * 1e6
    assert rel_ppm < 10.0, (
        f"device/host corpus estimate divergence: {rel_ppm:.3f} ppm")
    emit("perf/corpus/max_rel_dev_vs_host", rel_ppm,
         "ppm; must be < 10 (asserted)")

    # ingest throughput: vectorized sparse-batch padding (one flat numpy
    # scatter over the concatenated indices/values, no per-vector loop) and
    # the store's amortized in-place append.  rows/sec is the lake-ingest
    # figure of merit.
    n_pad = 64 if fast else 256
    ing = [sparse_pair(rng, n=600, nnz=120, overlap=0.1)[0]
           for _ in range(n_pad)]
    _, us = timed(lambda: pad_sparse_batch(ing), repeat=3)
    emit("perf/ingest/pad_rows_per_s", n_pad / (us / 1e6),
         f"rows={n_pad} nnz~120; vectorized flat scatter")

    # appending b rows into a P-row corpus writes b rows into preallocated
    # buffers (jax.lax.dynamic_update_slice, donated): no chunk-list
    # re-concatenation of all P rows.  On TPU donation makes this O(b) in-
    # place; XLA's CPU client lacks donation, so CPU pays one buffer copy.
    def append_row_us(prefill: int) -> float:
        m_s = 64
        rngl = np.random.default_rng(5)
        st = CorpusStore(m=m_s, fields=1, min_capacity=2 * prefill + 16)
        st.append(rngl.integers(0, 100, (prefill, m_s)).astype(np.int32),
                  rngl.normal(size=(prefill, m_s)).astype(np.float32),
                  np.ones(prefill, np.float32),
                  rngl.integers(0, 100, (prefill, m_s)).astype(np.int32))
        row = (rngl.integers(0, 100, (1, m_s)).astype(np.int32),
               rngl.normal(size=(1, m_s)).astype(np.float32),
               np.ones(1, np.float32),
               rngl.integers(0, 100, (1, m_s)).astype(np.int32))

        def append_and_sync():
            # block on the written buffers: append dispatches async, and an
            # unsynchronized timing would only measure Python dispatch
            st.append(*row)
            jax.block_until_ready(st.buffers())

        append_and_sync()               # warm the (capacity, 1) jit entry
        best = float("inf")
        for _ in range(5):
            _, us = timed(append_and_sync)
            best = min(best, us)
        return best

    p_small, p_large = (16, 128) if fast else (16, 1024)
    us_small = append_row_us(p_small)
    us_large = append_row_us(p_large)
    emit("perf/ingest/append_row_small", us_small,
         f"1-row append into a {p_small}-row corpus, no growth")
    emit("perf/ingest/append_row_large", us_large,
         f"1-row append into a {p_large}-row corpus, no growth; "
         f"O(b) on TPU (donation), buffer copy on CPU")

    # per-family build throughput on a fat-row lake: every family's
    # sketch_rows on the same ~4096-nonzero vectors, storage-matched to
    # icws m=64.  This is the constant-time-ingest gate: the DMH kernel's
    # O(nnz + m) binning pass must beat the ICWS O(nnz * m) broadcast by
    # >= 5x on this geometry (both run the Pallas interpreter here, so the
    # ratio measures kernel work, not TPU silicon).
    bt_B, bt_nnz, bt_reps = (8, 512, 1) if fast else (48, 4096, 3)
    bt_storage = wmh_storage(64)
    bt_rng = np.random.default_rng(37)
    bt_dom = 2 ** 31
    bt_vecs = []
    for _ in range(bt_B):
        bi = np.unique(bt_rng.integers(0, bt_dom, size=bt_nnz))
        bt_vecs.append(SparseVec.from_pairs(
            bi, bt_rng.normal(size=bi.size), bt_dom))
    build_rows = {}
    for name in FAMILY_NAMES:
        bfam = make_family(name, storage=bt_storage, seed=11)
        jax.block_until_ready(bfam.sketch_rows(bt_vecs))   # warm jit/kernel
        best = float("inf")
        for _ in range(bt_reps):
            t0 = time.perf_counter()
            jax.block_until_ready(bfam.sketch_rows(bt_vecs))
            best = min(best, time.perf_counter() - t0)
        build_rows[name] = bt_B / best
        emit(f"perf/ingest/build_rows_per_s/{name}", build_rows[name],
             f"rows={bt_B} nnz~{bt_nnz} storage={bt_storage:.0f} "
             f"(icws m=64) interpret=True")
    build_speedup = build_rows["dmh"] / build_rows["icws"]
    emit("perf/ingest/dmh_vs_icws_build_speedup", build_speedup,
         f"x; dmh rows/s over icws rows/s at nnz~{bt_nnz}, "
         + ("fast lane" if fast else "must be >= 5 (asserted)"))
    if not fast:
        assert build_speedup >= 5.0, (
            f"dmh build must be >= 5x icws rows/s at nnz~{bt_nnz}, m=64; "
            f"got {build_speedup:.2f}x")

    # single-vs-batched serving: the §1.3 endpoint end to end at corpus
    # scale.  Sequential serving pays one ICWS sketch launch + six
    # one-vs-many estimate launches per query; search_batch folds a whole
    # micro-batch into one [3Q, N] sketch launch + ONE fused multi-field
    # many-vs-many launch whose [bq, bp, bm] blocks amortize per-step costs
    # across queries.  Min-of-reps timing: this container's wall clock is
    # noisy and the floor is the honest per-path cost.
    n_tables, Qn, ms, reps = (48, 4, 64, 1) if fast else (1024, 16, 128, 3)
    n_rows = 100 if fast else 150
    svc = SketchSearchService(m=ms, seed=7, keep_host_oracle=False)
    lake_rng = np.random.default_rng(31)
    base_keys = np.arange(n_rows)
    sig = lake_rng.normal(size=n_rows)
    for t in range(n_tables):
        svc.ingest(f"t{t}", base_keys,
                   sig + (0.1 + 0.2 * t) * lake_rng.normal(size=n_rows))
    queries = [(base_keys, sig + 0.1 * lake_rng.normal(size=n_rows))
               for _ in range(Qn)]
    # warm both jit/kernel caches before timing
    svc.search(*queries[0], top_k=3, min_join=10)
    svc.search_batch(queries, top_k=3, min_join=10, micro_batch=Qn)

    s_seq, s_bat = float("inf"), float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        seq_res = [svc.search(k, v, top_k=3, min_join=10) for k, v in queries]
        s_seq = min(s_seq, time.perf_counter() - t0)
        t0 = time.perf_counter()
        bat_res = svc.search_batch(queries, top_k=3, min_join=10,
                                   micro_batch=Qn)
        s_bat = min(s_bat, time.perf_counter() - t0)
    assert bat_res == seq_res, "batched results diverged from sequential"
    qps_seq = Qn / s_seq
    qps_bat = Qn / s_bat
    emit("perf/serve/search_sequential", s_seq / Qn * 1e6,
         f"Q={Qn} tables={n_tables} m={ms} qps={qps_seq:.2f}")
    emit("perf/serve/search_batched", s_bat / Qn * 1e6,
         f"Q={Qn} tables={n_tables} m={ms} qps={qps_bat:.2f} micro_batch={Qn}")
    speedup = qps_bat / qps_seq
    emit("perf/serve/batched_speedup", speedup,
         f"x; batched qps / sequential qps at Q={Qn}")
    if Qn >= 16:
        assert speedup >= 2.0, (
            f"batched serving must be >= 2x sequential at Q={Qn}; "
            f"got {speedup:.2f}x")

    # family comparison: the paper's head-to-head LIVE on the serving
    # kernels.  One storage budget sizes every family (registry
    # accounting), so the error axis is storage-fair; sparse low-overlap
    # vectors are the Theorem-2 regime where weighted MinWise sampling
    # beats the linear sketches.
    f_rng = np.random.default_rng(41)
    n_pairs = 8 if fast else 32
    f_pairs = [sparse_pair(f_rng, n=10_000, nnz=1_000, overlap=0.05)
               for _ in range(n_pairs)]
    f_true = np.array([inner_fast(a, b) for a, b in f_pairs])
    f_scale = np.array([a.norm() * b.norm() for a, b in f_pairs])
    fam_err = {}
    for storage in (100, 400):
        for name in FAMILY_NAMES:
            fam = make_family(name, storage=storage, seed=5)
            qa = tuple(c[None] for c in
                       fam.sketch_rows([a for a, _ in f_pairs]))
            cb = tuple(c[None] for c in
                       fam.sketch_rows([b for _, b in f_pairs]))
            est = np.asarray(fam.estimate_fields(qa, cb, qmap=(0,),
                                                 cmap=(0,))[0], np.float64)
            err = float(np.mean(np.abs(np.diag(est) - f_true) / f_scale))
            fam_err[(name, storage)] = err
            # feed the rolling quality gauge: every pair is one sampled
            # estimate-vs-exact observation, normalized by the paper's
            # ||a||*||b|| scale, so the exported snapshot carries a
            # quality.ppm_error EWMA per family
            for e_i, t_i, s_i in zip(np.diag(est), f_true, f_scale):
                _obs.record_sample(name, float(e_i), float(t_i),
                                   scale=float(s_i))
            emit(f"perf/family/err/{name}/storage{storage}", err * 1e6,
                 f"mean |est-true|/(|a||b|) ppm; pairs={n_pairs} "
                 f"overlap=0.05 storage-matched")
    for storage in (100, 400):
        # the paper's claim, enforced on the serving kernels: WMH/ICWS
        # beats both linear sketches on sparse low-overlap corpora
        icws_e = fam_err[("icws", storage)]
        for other in ("cs", "jl"):
            assert icws_e < fam_err[(other, storage)], (
                f"icws must beat {other} at storage={storage}: "
                f"{icws_e:.5f} vs {fam_err[(other, storage)]:.5f}")
        # the sampling-sketch claim (Daliri et al. 2309.16157), enforced
        # the same way: threshold/priority sampling also beat the linear
        # sketches in this regime (measured ~2-75x lower error here)
        for samp in ("ts", "ps"):
            for lin in ("cs", "jl"):
                assert fam_err[(samp, storage)] <= fam_err[(lin, storage)], (
                    f"{samp} must beat {lin} at storage={storage}: "
                    f"{fam_err[(samp, storage)]:.5f} vs "
                    f"{fam_err[(lin, storage)]:.5f}")
        # constant-time ingest must not buy speed with accuracy: the
        # densified one-permutation sketch stays within 1.5x of the full
        # ICWS error at every storage budget.  A 1.5x margin needs the
        # full 32-pair lake -- the 8-pair fast lane still emits the rows
        # but only the nightly full run asserts (same as the build gate).
        if not fast:
            assert fam_err[("dmh", storage)] <= 1.5 * icws_e, (
                f"dmh error must stay within 1.5x of icws at "
                f"storage={storage}: {fam_err[('dmh', storage)]:.5f} vs "
                f"{icws_e:.5f}")

    # same corpus served under every family: end-to-end queries/sec (one
    # lake ingested per family, identical tables and queries)
    f_tables, f_Q, f_m = (24, 4, 64) if fast else (256, 16, 128)
    f_rows = 100 if fast else 150
    lake_rng2 = np.random.default_rng(43)
    fk = np.arange(f_rows)
    fsig = lake_rng2.normal(size=f_rows)
    fam_tables = [(f"t{t}", fk,
                   fsig + (0.1 + 0.2 * t) * lake_rng2.normal(size=f_rows))
                  for t in range(f_tables)]
    f_queries = [(fk, fsig + 0.1 * lake_rng2.normal(size=f_rows))
                 for _ in range(f_Q)]
    for name in FAMILY_NAMES:
        fsvc = SketchSearchService(m=f_m, seed=7, family=name,
                                   keep_host_oracle=False)
        fsvc.ingest_many(fam_tables)
        fsvc.search_batch(f_queries, top_k=3, min_join=10,
                          micro_batch=f_Q)            # warm jit/kernel caches
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fsvc.search_batch(f_queries, top_k=3, min_join=10,
                              micro_batch=f_Q)
            best = min(best, time.perf_counter() - t0)
        emit(f"perf/family/qps/{name}", best / f_Q * 1e6,
             f"batched qps={f_Q / best:.2f} tables={f_tables} m={f_m} "
             f"storage-matched interpret=True")

    # parallel lake build: shard-and-merge vs single-stream.  The deployment
    # this simulates: the lake lives key-partitioned across k producer
    # hosts (events routed to owners by folded key at write time -- the
    # standard log/stream partition layout, paid once when the data lands,
    # not per sketch build), each host sketches its own partition, and the
    # shard corpora compact through the pairwise merge tree.  The per-build
    # critical path is therefore the SLOWEST shard sketch + the merge
    # tree; the one-pass coordinated partition (partition_by_key -- what a
    # producer runs at routing time) is timed and reported for reference
    # but is data layout, not per-build work.  The gate: sketching 1/k of
    # the coordinates per worker + merging beats sketching everything in
    # one stream, i.e. the merge tree is cheap enough that parallel builds
    # actually pay off.
    k_shards = 4
    n_lake, lake_nnz = (96, 400) if fast else (4096, 8000)
    lk_rng = np.random.default_rng(47)
    lake_dom = 2 ** 31
    lake_vecs = []
    for _ in range(n_lake):
        li = np.unique(lk_rng.integers(0, lake_dom, size=lake_nnz))
        lake_vecs.append(SparseVec.from_pairs(
            li, lk_rng.normal(size=li.size), lake_dom))
    ts_fam = TSFamily(slots=64, seed=7)

    def single_stream_build():
        st = CorpusStore(family=ts_fam, fields=1)
        st.append(*ts_fam.sketch_rows(lake_vecs))
        return st

    # median-of-N timing (1 in the fast lane): both sides of the gate run
    # the same number of repeats and compare medians via the obs histogram
    # primitives -- a single contended wall clock on this container has
    # failed unrelated PRs before, and the median is robust to one bad rep.
    lake_reps = 1 if fast else 3
    single_stream_build()                       # warm append jit entries
    st_single, h_single = timed_median(single_stream_build,
                                       repeat=lake_reps)
    t_single = h_single.quantile(0.5)

    t0 = time.perf_counter()
    parts = [partition_by_key(v, k_shards) for v in lake_vecs]
    t_part = time.perf_counter() - t0

    def build_shard(s):
        sst = CorpusStore(family=ts_fam, fields=1)
        sst.append(*ts_fam.sketch_rows([p[s] for p in parts]))
        return sst

    def merge_tree(stores):
        stores = list(stores)
        while len(stores) > 1:
            nxt = [merge_stores(stores[i], stores[i + 1])
                   for i in range(0, len(stores) - 1, 2)]
            if len(stores) % 2:
                nxt.append(stores[-1])
            stores = nxt
        return stores[0]

    # warm the shard-shape sketch + merged-append jit entries once
    merge_tree([build_shard(s) for s in range(k_shards)])
    h_crit = Histogram("bench.lake_critical_path")
    shard_times, t_merge, st_merged = [], 0.0, None
    for _ in range(lake_reps):
        shard_times, shard_stores = [], []
        for s in range(k_shards):
            t0 = time.perf_counter()
            shard_stores.append(build_shard(s))
            shard_times.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        st_merged = merge_tree(shard_stores)
        t_merge = time.perf_counter() - t0
        h_crit.record(max(shard_times) + t_merge)
    # union re-subsampling reproduces the single-stream sample (keys and
    # values bitwise; taus to f32 rounding) -- the speedup is not bought
    # with a different corpus
    k1, v1, _ = (np.asarray(c) for c in st_single.field_arrays())
    k2, v2, _ = (np.asarray(c) for c in st_merged.field_arrays())
    assert np.array_equal(k1, k2) and np.array_equal(v1, v2), (
        "sharded lake build diverged from single-stream")
    t_parallel = h_crit.quantile(0.5)
    lake_speedup = t_single / t_parallel
    emit("perf/lake/single_stream_s", t_single,
         f"tables={n_lake} nnz~{lake_nnz} ts slots=64 "
         f"median-of-{lake_reps}")
    emit("perf/lake/parallel_critical_path_s", t_parallel,
         f"median-of-{lake_reps} of max-shard + merge-tree (last rep: "
         f"{max(shard_times):.3f}s + {t_merge:.3f}s); k={k_shards} "
         f"(producer-side one-pass partition: {t_part:.3f}s, data "
         f"layout, not per-build work)")
    emit("perf/lake/parallel_build_speedup", lake_speedup,
         f"x; single-stream / critical path medians, k={k_shards} "
         f"tables={n_lake}")
    if not fast:
        assert lake_speedup >= 1.5, (
            f"{k_shards}-way parallel lake build must be >= 1.5x "
            f"single-stream at {n_lake} tables; got {lake_speedup:.2f}x")

    # multi-tenant isolation: a tenant-scoped query against the shared
    # arena vs the same query against a dedicated single-tenant service.
    # Contiguous tenants serve off a buffer slice, so per-query cost must
    # track the TENANT's rows, not the arena -- co-residency is close to
    # free.
    tn_tables, tn_Q, tn_m = (24, 4, 64) if fast else (128, 8, 128)
    tn_rows = 100 if fast else 150
    tn_rng = np.random.default_rng(53)
    tk = np.arange(tn_rows)
    tsig = tn_rng.normal(size=tn_rows)
    tn_tabs = {
        t: [(f"{t}{i}", tk,
             tsig + (0.1 + 0.2 * i) * tn_rng.normal(size=tn_rows))
            for i in range(tn_tables)]
        for t in ("a", "b")}
    shared_svc = SketchSearchService(m=tn_m, seed=7, keep_host_oracle=False)
    for t, tabs in tn_tabs.items():
        shared_svc.ingest_many(tabs, tenant=t)          # contiguous ranges
    dedicated_svc = SketchSearchService(m=tn_m, seed=7,
                                        keep_host_oracle=False)
    dedicated_svc.ingest_many(tn_tabs["a"])
    tn_queries = [(tk, tsig + 0.1 * tn_rng.normal(size=tn_rows))
                  for _ in range(tn_Q)]

    def run_shared():
        return shared_svc.search_batch(tn_queries, top_k=3, min_join=10,
                                       micro_batch=tn_Q, tenant="a")

    def run_dedicated():
        return dedicated_svc.search_batch(tn_queries, top_k=3, min_join=10,
                                          micro_batch=tn_Q)

    assert run_shared() == run_dedicated(), (          # warms both caches
        "tenant-scoped arena results diverged from the dedicated store")
    # interleaved median-of-5: alternating the two paths inside one loop
    # decorrelates container CPU-contention drift, and the p50 (exact at 5
    # samples) is robust where min-of-5 tracked a single lucky floor.  The
    # gate is on the p50 and loosened from 5% to 15%: the old min-of-5 5%
    # gate tripped on unrelated PRs (8.49% observed at a passing HEAD).
    h_sh = Histogram("bench.tenant_shared")
    h_de = Histogram("bench.tenant_dedicated")
    for _ in range(5):
        t0 = time.perf_counter()
        run_shared()
        h_sh.record(time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_dedicated()
        h_de.record(time.perf_counter() - t0)
    t_sh, t_de = h_sh.quantile(0.5), h_de.quantile(0.5)
    overhead_pct = (t_sh / t_de - 1.0) * 100.0
    emit("perf/tenant/query_shared_arena", t_sh / tn_Q * 1e6,
         f"tenant-scoped batch query; arena rows={2 * tn_tables} "
         f"tenant rows={tn_tables} m={tn_m}")
    emit("perf/tenant/query_dedicated", t_de / tn_Q * 1e6,
         f"dedicated single-tenant store, rows={tn_tables} m={tn_m}")
    emit("perf/tenant/isolation_overhead_pct", overhead_pct,
         "%; (shared arena / dedicated - 1) * 100, median-of-5")
    if not fast:
        assert overhead_pct < 15.0, (
            f"tenant isolation p50 overhead must stay < 15%; "
            f"got {overhead_pct:.2f}%")

    # million-row corpora: bit-packed resident layout.  The packed
    # CorpusStore keeps each family's bf16-halfword wire format and decodes
    # inside the estimate kernels; what CI can measure is bytes/row (exact,
    # from the component specs that size the buffers) plus a packed-corpus
    # scan at CI-safe row counts -- the 10^6-row resident footprint is the
    # same bytes/row, extrapolated.  Gates: ICWS packed bytes/row <= 60% of
    # unpacked (values plane halved + the argkeys re-leveling sidecar
    # dropped); the sampling families <= 80% (their 31-bit exact-match keys
    # are the information floor and must stay full-width).  The packed
    # store's rows must equal `pack_rows` of the unpacked store's rows bit
    # for bit -- the layout saves bytes, it does not fork the corpus.
    sc_tables, sc_Q, sc_m = (24, 4, 64) if fast else (128, 8, 128)
    sc_rows = 100
    sc_rng = np.random.default_rng(59)
    sck = np.arange(sc_rows)
    scsig = sc_rng.normal(size=sc_rows)
    sc_tabs = [(f"t{i}", sck,
                scsig + (0.1 + 0.2 * i) * sc_rng.normal(size=sc_rows))
               for i in range(sc_tables)]
    sc_queries = [(sck, scsig + 0.1 * sc_rng.normal(size=sc_rows))
                  for _ in range(sc_Q)]
    ratio_gate = {"icws": 0.60, "ts": 0.80}
    for name in ("icws", "ts"):
        svc_u = SketchSearchService(m=sc_m, seed=7, family=name,
                                    keep_host_oracle=False)
        svc_p = SketchSearchService(m=sc_m, seed=7, family=name,
                                    keep_host_oracle=False, packed=True)
        svc_u.ingest_many(sc_tabs)
        svc_p.ingest_many(sc_tabs)
        bpr_u = svc_u.index.store.bytes_per_row()
        bpr_p = svc_p.index.store.bytes_per_row()
        ratio = bpr_p / bpr_u
        emit(f"perf/scale/bytes_per_row_ratio/{name}", ratio,
             f"packed {bpr_p} B / unpacked {bpr_u} B per field row; "
             f"must be <= {ratio_gate[name]:.2f} (asserted)")
        assert ratio <= ratio_gate[name], (
            f"{name} packed layout must keep <= {ratio_gate[name]:.0%} of "
            f"unpacked bytes/row; got {ratio:.2%} ({bpr_p}/{bpr_u})")
        emit(f"perf/scale/resident_mb_at_1e6_rows/{name}",
             bpr_p * 3 * 1e6 / 2 ** 20,
             f"extrapolated packed MB for 10^6 tables x 3 fields "
             f"(unpacked {bpr_u * 3 * 1e6 / 2 ** 20:.0f} MB)")
        fam = svc_p.index.family
        for pu, pp in zip(fam.pack_rows(svc_u.index.store.field_arrays()),
                          svc_p.index.store.field_arrays()):
            assert np.array_equal(np.asarray(pu), np.asarray(pp)), (
                f"{name} packed store rows diverged from pack_rows of the "
                f"unpacked store")
        # packed-corpus scan throughput (unpack-in-kernel on the hot path)
        svc_p.search_batch(sc_queries, top_k=3, min_join=10,
                           micro_batch=sc_Q)          # warm jit/kernel caches
        svc_u.search_batch(sc_queries, top_k=3, min_join=10,
                           micro_batch=sc_Q)
        t_p, t_u = float("inf"), float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            svc_p.search_batch(sc_queries, top_k=3, min_join=10,
                               micro_batch=sc_Q)
            t_p = min(t_p, time.perf_counter() - t0)
            t0 = time.perf_counter()
            svc_u.search_batch(sc_queries, top_k=3, min_join=10,
                               micro_batch=sc_Q)
            t_u = min(t_u, time.perf_counter() - t0)
        emit(f"perf/scale/packed_scan_qps/{name}", sc_Q / t_p,
             f"batched packed-corpus scan; tables={sc_tables} m={sc_m} "
             f"unpacked qps={sc_Q / t_u:.2f} interpret=True")

    # roofline-autotuned block sizes vs the declared defaults, on the fused
    # multi-field estimate kernel the serving path launches.  The committed
    # cache (src/repro/roofline/block_cache.json) was produced by the cost
    # model in repro.roofline.autotune; in interpret mode per-grid-step
    # overhead dominates, so fewer/larger blocks must beat-or-match the
    # defaults -- asserted, since ops resolves these exact blocks at serve
    # time.  resolve() clamps row blocks to this launch's padded rows (the
    # same clamp ops applies), so the comparison is what production sees.
    at_m = 128
    at_Q, at_P = (8, 256) if fast else (16, 1024)
    at_rng = np.random.default_rng(61)
    at_fq = jnp.asarray(at_rng.integers(0, 1000, (3, at_Q, at_m)), jnp.int32)
    at_vq = jnp.asarray(at_rng.random((3, at_Q, at_m)), jnp.float32)
    at_fc = jnp.asarray(at_rng.integers(0, 1000, (3, at_P, at_m)), jnp.int32)
    at_vc = jnp.asarray(at_rng.random((3, at_P, at_m)), jnp.float32)
    at_qmap, at_cmap = (0, 1, 0, 2, 0, 1), (0, 0, 1, 0, 2, 1)
    tuned = autotune.resolve("estimate_fields", jax.default_backend(),
                             {"m": at_m},
                             clamp={"bq": (at_Q, 8), "bp": (at_P, 128)})

    def fields_launch(blocks):
        return estimate_fields_pallas(
            at_fq, at_vq, at_fc, at_vc, qmap=at_qmap, cmap=at_cmap,
            **blocks)[0].block_until_ready()

    # interleaved median-of-5 (2 in the fast lane): default and tuned
    # launches alternate inside one loop so a contention burst hits both
    # sides equally, and the gate compares p50s -- min-of-N made this the
    # flakiest gate in the suite when one default rep caught a quiet slice.
    fields_launch({})                      # warm both jit/kernel caches
    if tuned:
        fields_launch(tuned)
    at_reps = 2 if fast else 5
    h_def = Histogram("bench.autotune_default")
    h_tun = Histogram("bench.autotune_tuned")
    for _ in range(at_reps):
        t0 = time.perf_counter()
        fields_launch({})
        h_def.record(time.perf_counter() - t0)
        if tuned:
            t0 = time.perf_counter()
            fields_launch(tuned)
            h_tun.record(time.perf_counter() - t0)
    t_def = h_def.quantile(0.5)
    n_pairs_at = len(at_qmap) * at_Q * at_P
    emit("perf/autotune/default_pairs_per_s", n_pairs_at / t_def,
         f"fused fields kernel, default blocks; G=6 Q={at_Q} P={at_P} "
         f"m={at_m} interpret=True median-of-{at_reps}")
    if tuned:
        t_tun = h_tun.quantile(0.5)
        emit("perf/autotune/tuned_pairs_per_s", n_pairs_at / t_tun,
             f"blocks={tuned} from the committed roofline cache")
        emit("perf/autotune/speedup", t_def / t_tun,
             "x; tuned / default p50 throughput on the fused fields "
             "kernel, must be >= ~1 (asserted)")
        assert t_tun <= t_def * 1.05, (
            f"autotuned blocks {tuned} must beat-or-match the defaults on "
            f"the fused fields kernel (median-of-{at_reps}): "
            f"{t_tun * 1e3:.1f}ms tuned vs {t_def * 1e3:.1f}ms default")
    else:
        emit("perf/autotune/tuned_pairs_per_s", 0.0,
             f"no cache entry for backend={jax.default_backend()} "
             f"m={at_m}; defaults in use")

    # the no-op guarantee, measured: with observability disabled every
    # instrumented ops launch pays exactly one wrapper crossing (an
    # enabled() check + delegation).  Time that crossing in isolation over
    # 10k calls, scale by the ~8 wrapped launches a single search makes
    # (query sketch + six field estimates + top-k), and bound it against
    # the median sequential query latency measured above.  The 2% gate is
    # the tentpole's acceptance bar; the measured figure is typically
    # orders of magnitude below it.
    was_enabled = _obs.enabled()
    _obs.disable()
    try:
        def bare():
            return None

        wrapped = _obs.instrumented("icws_estimate")(bare)
        n_calls = 10_000
        t0 = time.perf_counter()
        for _ in range(n_calls):
            wrapped()
        t_wrapped = (time.perf_counter() - t0) / n_calls
        t0 = time.perf_counter()
        for _ in range(n_calls):
            bare()
        t_bare = (time.perf_counter() - t0) / n_calls
    finally:
        if was_enabled:
            _obs.enable()
    wrapper_s = max(t_wrapped - t_bare, 0.0)
    med_query_s = max(svc.stats.query_hist.quantile(0.5), 1e-9)
    obs_overhead_pct = wrapper_s * 8 / med_query_s * 100.0
    emit("perf/obs/disabled_wrapper_ns", wrapper_s * 1e9,
         f"per-call cost of the disabled @instrumented crossing, "
         f"{n_calls} calls")
    emit("perf/obs/disabled_overhead_pct_of_query", obs_overhead_pct,
         f"%; 8 wrapped launches/query vs median sequential query "
         f"{med_query_s * 1e3:.2f}ms; must be < 2 (asserted)")
    assert obs_overhead_pct < 2.0, (
        f"disabled-path instrumentation overhead must stay < 2% of a "
        f"query; got {obs_overhead_pct:.4f}%")
