"""Throughput benchmarks for the sketching hot paths (host + device/interp).

Production framing: dataset-search ingests a lake by sketching every column
(sketch/s matters) and serves queries by estimating against the whole corpus
(pair/s matters).  Device-path numbers on this CPU container exercise the
Pallas interpreter and the jit pipeline, not TPU silicon -- they validate
scaling shape, not absolute speed (the roofline analysis covers TPU).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import make, stack_wmh
from repro.data.synthetic import sparse_pair
from repro.kernels import ops
from repro.kernels.icws_sketch import icws_sketch_pallas

from .common import emit, timed


def run(fast: bool = False):
    rng = np.random.default_rng(23)
    pairs = [sparse_pair(rng, overlap=0.1) for _ in range(2 if fast else 4)]
    vecs = [v for p in pairs for v in p]

    # host sketch throughput per method
    for method in ("wmh", "mh", "kmv", "jl", "cs", "icws"):
        sk = make(method, 400, seed=0)
        _, us = timed(lambda: [sk.sketch(v) for v in vecs])
        emit(f"perf/sketch/{method}", us / len(vecs),
             f"nnz={vecs[0].nnz} storage=400")

    # batched estimation throughput (the corpus-query hot loop)
    sk = make("wmh", 400, seed=0)
    sketches = [sk.sketch(v) for v in vecs]
    A = stack_wmh(sketches * 50)
    B = stack_wmh(sketches[::-1] * 50)
    _, us = timed(sk.estimate_batch, A, B, repeat=3)
    emit("perf/estimate_batch/wmh", us / A.norm.shape[0], f"pairs={A.norm.shape[0]}")

    # device (Pallas interpret) sketch + fused estimate
    B_, N, m = 4, 512, 256
    w = jnp.asarray(rng.random((B_, N)), jnp.float32)
    w = w / w.sum(axis=1, keepdims=True)
    keys = jnp.asarray(rng.integers(0, 2**31 - 1, (B_, N)), jnp.int32)
    vals = jnp.sqrt(w)
    out = icws_sketch_pallas(w, keys, vals, m=m, seed=0, interpret=True)
    _, us = timed(lambda: icws_sketch_pallas(w, keys, vals, m=m, seed=0,
                                             interpret=True)[0].block_until_ready())
    emit("perf/kernel/icws_sketch", us / B_, f"B={B_} N={N} m={m} interpret=True")

    fp, val, _ = out
    na = jnp.ones((B_,), jnp.float32)
    _, us = timed(lambda: ops.icws_estimate(fp, val, na, fp, val, na)
                  .block_until_ready())
    emit("perf/kernel/estimate", us / B_, f"pairs={B_} m={m} interpret=True")
